#!/usr/bin/env bash
# Tier-1 CI gate: the fast offline test suite + the benchmark smoke run.
#
#   scripts/ci.sh            # what CI runs
#   scripts/ci.sh --runslow  # + the multi-minute XLA compile cells
#   scripts/ci.sh --mesh     # + the mesh-marked tests under 8 forced
#                            #   host devices (XLA_FLAGS)
#   scripts/ci.sh --analyze  # + the static program-contract checker
#                            #   (python -m repro.analysis --strict)
#   scripts/ci.sh --obs      # only the obs stage: two recorded smoke
#                            #   runs, JSONL schema validation, Perfetto
#                            #   export round-trip, and a run diff
#   scripts/ci.sh --policy   # only the policy stage: the repro.policy
#                            #   property tests + the gap-vs-uniform
#                            #   oracle-call convergence smoke row
#   scripts/ci.sh --serve    # only the serve stage: the repro.serve +
#                            #   viterbi tests, then the serving bench
#                            #   which must emit serve_p50_us_* /
#                            #   serve_p99_us_* / serve_throughput_*
#                            #   rows with the batched path beating the
#                            #   one-at-a-time baseline
#   scripts/ci.sh --async    # only the async stage: the async-pipeline
#                            #   test suite, the async_bench smoke
#                            #   (oracle overlap >= 0.5 under the slow-
#                            #   oracle CostModel, <= 2 dispatches +
#                            #   1 host sync, fold-scatter bitwise), and
#                            #   the strict analyzer (rule J009 proves
#                            #   the two-program split statically)
#
# The obs, policy, serve, and async stages also run as part of the
# default flow (after the test suite, before/with the benchmark smoke)
# so a broken recorder/CLI, a gap-sampling regression, a serving
# regression, or a pipelining regression fails CI.
#
# The smoke benchmarks exercise the public Solver path end to end,
# including the fused score+select kernel vs the two-step path, the
# sharded gram engine's dispatch contract, and the policy layer's
# gap-proportional sampler.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MESH=0
ANALYZE=0
OBS_ONLY=0
POLICY_ONLY=0
SERVE_ONLY=0
ASYNC_ONLY=0
ARGS=()
for a in "$@"; do
  if [[ "$a" == "--mesh" ]]; then MESH=1
  elif [[ "$a" == "--analyze" ]]; then ANALYZE=1
  elif [[ "$a" == "--obs" ]]; then OBS_ONLY=1
  elif [[ "$a" == "--policy" ]]; then POLICY_ONLY=1
  elif [[ "$a" == "--serve" ]]; then SERVE_ONLY=1
  elif [[ "$a" == "--async" ]]; then ASYNC_ONLY=1
  else ARGS+=("$a"); fi
done

obs_stage() {
  # End-to-end obs check: record two tiny runs, validate them against
  # the JSONL schema, round-trip the Chrome-trace/Perfetto export, and
  # summarize + diff them through the CLI.
  local dir
  dir="$(mktemp -d)"
  trap 'rm -rf "$dir"' RETURN
  python -m repro.obs --smoke-run "$dir/a.jsonl" --algo mpbcfw --iters 5
  python -m repro.obs --smoke-run "$dir/b.jsonl" --algo mpbcfw-gram --iters 5
  python -m repro.obs --validate "$dir/a.jsonl" "$dir/b.jsonl"
  python -m repro.obs --export-trace "$dir/a.jsonl" -o "$dir/a.trace.json"
  python - "$dir/a.trace.json" <<'EOF'
import json, sys
events = json.load(open(sys.argv[1]))["traceEvents"]
assert events, "empty Perfetto export"
assert any(e.get("ph") == "X" for e in events), "no span events"
print(f"{sys.argv[1]}: round-trip OK ({len(events)} events)")
EOF
  python -m repro.obs "$dir/a.jsonl"
  python -m repro.obs --diff "$dir/a.jsonl" "$dir/b.jsonl"
}

policy_stage() {
  # Policy-layer gate: the repro.policy property/parity tests, then the
  # paper-scenario convergence smoke which must emit a
  # gap_vs_uniform_oracle_calls_* row showing the gap-proportional
  # sampler reaching the fixed gap target in fewer exact-oracle calls
  # than uniform sampling on at least one scenario.
  python -m pytest -x -q tests/test_policy.py
  python -m benchmarks.paper_convergence --smoke
}

serve_stage() {
  # Serving gate: the serve/viterbi test suites (export round-trip,
  # batcher contracts, kernel-vs-NumPy properties), then the serving
  # bench, which must emit latency/throughput rows for every bundled
  # spec and show the batched bucketed path beating one-at-a-time
  # decode on throughput.
  python -m pytest -x -q tests/test_serve.py tests/test_viterbi.py
  local out
  out="$(mktemp)"
  python -m benchmarks.serving_bench --smoke | tee "$out"
  python - "$out" <<'EOF'
import sys
rows = {}
for line in open(sys.argv[1]):
    line = line.strip()
    if line:
        name, value = line.split(",")[:2]
        rows[name] = float(value)
for kind in ("chain", "multiclass", "graph"):
    for prefix in ("serve_p50_us_", "serve_p99_us_", "serve_throughput_"):
        assert prefix + kind in rows, f"missing {prefix + kind} row"
    speedup = rows[f"serve_batched_speedup_{kind}"]
    assert speedup > 1.0, \
        f"batched serving lost to one-at-a-time on {kind}: {speedup}x"
print("serve stage OK: batched path beats single-request decode")
EOF
  rm -f "$out"
}

async_stage() {
  # Async-pipelining gate: the mpbcfw-async / mpbcfw-shard-async test
  # suite (dual monotonicity under stragglers, bitwise resume, the
  # CollectiveTrace split regression), then the async bench smoke —
  # which asserts the pipeline hides >= 0.5 of the modeled oracle under
  # the slow-oracle CostModel at <= 2 dispatches + 1 host sync per
  # outer iteration and that the chunked fold-scatter is bit-identical
  # — and the strict analyzer whose rule J009 proves the
  # async_oracle/async_cache two-program split statically.
  python -m pytest -x -q -m "not mesh" tests/test_async.py
  python -m benchmarks.async_bench --smoke
  python -m repro.analysis --strict
}

if [[ "$OBS_ONLY" == 1 ]]; then
  obs_stage
  exit 0
fi

if [[ "$SERVE_ONLY" == 1 ]]; then
  serve_stage
  exit 0
fi

if [[ "$POLICY_ONLY" == 1 ]]; then
  policy_stage
  exit 0
fi

if [[ "$ASYNC_ONLY" == 1 ]]; then
  async_stage
  exit 0
fi

if [[ "$ANALYZE" == 1 ]]; then
  # Static gate first: traces every registered engine's fused programs,
  # cross-checks jaxpr/HLO collective budgets, lints src/.  Fails fast
  # (nonzero exit on any finding) before the test suite spends minutes.
  python -m repro.analysis --strict
fi

if [[ "$MESH" == 1 ]]; then
  # Split stages: the fast suite without the mesh-marked tests first,
  # then only the mesh-marked tests under 8 forced host devices (the
  # subprocess smokes force the count themselves; the stage-level flag
  # covers any in-process multi-device collection).
  python -m pytest -x -q -m "not mesh" ${ARGS[@]+"${ARGS[@]}"}
  obs_stage
  policy_stage
  serve_stage
  async_stage
  python -m benchmarks.run --smoke
  # The mesh-marked tests include the mpbcfw-shard-async subprocess
  # smoke (8 forced host devices), so the two-program split's dispatch
  # contract is exercised on a real multi-shard mesh here.
  XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest -x -q -m mesh ${ARGS[@]+"${ARGS[@]}"}
else
  python -m pytest -x -q ${ARGS[@]+"${ARGS[@]}"}
  obs_stage
  policy_stage
  serve_stage
  async_stage
  python -m benchmarks.run --smoke
fi
