#!/usr/bin/env bash
# Tier-1 CI gate: the fast offline test suite + the benchmark smoke run.
#
#   scripts/ci.sh            # what CI runs
#   scripts/ci.sh --runslow  # + the multi-minute XLA compile cells
#
# pytest.ini keeps the deprecated driver.run shim's DeprecationWarning
# filtered (its firing is itself asserted by tests/test_api.py); the
# smoke benchmarks exercise the public Solver path end to end.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"
python -m benchmarks.run --smoke
