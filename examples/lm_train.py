"""End-to-end LM training driver example.

Default: a reduced qwen2-family model for a few hundred steps on this host
with checkpoint/restart.  ``--params 100000000`` scales the family config
to ~100M params (the assignment's end-to-end scenario — slow on 1 CPU
core; the pod-scale path is the dry-run + launch/train.py on real chips).

    PYTHONPATH=src python examples/lm_train.py --steps 300
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import train_lm  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--params", type=int, default=0,
                    help="scale width to ~this many params (0 = reduced)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()
    out = train_lm(args.arch, args.steps, args.batch_size, args.seq_len,
                   reduced=args.params == 0, ckpt_dir=args.ckpt_dir,
                   save_every=100, target_params=args.params)
    first, last = out["losses"][0][1], out["final_loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
