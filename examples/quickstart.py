"""Quickstart: train a multiclass SSVM with MP-BCFW and compare to BCFW.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro.core import driver                     # noqa: E402
from repro.core.oracles import multiclass         # noqa: E402
from repro.core.selection import CostModel        # noqa: E402
from repro.data import synthetic                  # noqa: E402


def main():
    x, y = synthetic.usps_like(n=300, f=64, num_classes=10, seed=0)
    problem = multiclass.make_problem(jnp.asarray(x), jnp.asarray(y), 10)
    lam = 1.0 / problem.n

    print("== BCFW (baseline) vs MP-BCFW (paper) — same oracle budget ==")
    for algo in ("bcfw", "mpbcfw"):
        cfg = driver.RunConfig(lam=lam, algo=algo, max_iters=10, cap=32,
                               cost_model=CostModel(oracle_cost=0.02,
                                                    plane_cost=1e-4))
        res = driver.run(problem, cfg)
        last = res.trace[-1]
        print(f"{algo:8s}: exact oracle calls {last.n_exact:5d}  "
              f"approx steps {last.n_approx:6d}  "
              f"duality gap {last.gap:.5f}  dual {last.dual:.5f}")

    # accuracy of the learned predictor
    cfg = driver.RunConfig(lam=lam, algo="mpbcfw-avg", max_iters=10, cap=32,
                           cost_model=CostModel())
    res = driver.run(problem, cfg)
    w = res.w_avg.reshape(10, -1)
    pred = np.argmax(x @ w.T, axis=1)
    print(f"train accuracy (mpbcfw-avg): {np.mean(pred == y):.3f}")


if __name__ == "__main__":
    main()
