"""Quickstart: train structural SVMs through the public ``repro.api``.

    PYTHONPATH=src python examples/quickstart.py

Three layers, one seam each:

  * **Tasks** are :class:`repro.api.OracleSpec` subclasses (joint feature
    map + loss + loss-augmented decode); ``repro.api.build_problem``
    assembles the max-oracle.  The bundled specs cover the paper's three
    scenarios (multiclass / chain / graph); a custom task is a ~20-line
    spec — demoed below.
  * **Algorithms** are engines in the ``repro.api`` registry
    (``repro.api.algorithms()`` lists them; third parties add their own
    with ``register_engine`` — no core edits):

    ================== ======================================================
    name               what it runs
    ================== ======================================================
    fw                 batch Frank-Wolfe (paper Alg. 1)
    ssg                stochastic subgradient baseline
    bcfw / bcfw-avg    block-coordinate FW (Alg. 2), optionally averaged
    mpbcfw             multi-plane BCFW (Alg. 3) — one fused program per
                       outer iteration (exact pass + slope-ruled
                       approximate batch), one host sync per iteration
    mpbcfw-avg         + two-track weighted averaging (Sec. 3.6)
    mpbcfw-gram        + the Sec-3.5 Gram-cache inner loop (with
                       ``RunConfig.mesh`` it resolves to the sharded
                       gram engine)
    mpbcfw-shard       mpbcfw on a 1-D data mesh (``RunConfig.mesh``):
                       tau-nice exact epoch + sharded approximate batch;
                       bit-for-bit ``mpbcfw`` on a 1-device mesh
    mpbcfw-shard-avg   + averaging
    mpbcfw-shard-tau   explicit tau-nice chunk size via ``RunConfig.tau``
    mpbcfw-shard-gram  the Sec-3.5 scheme on the mesh-sharded plane
                       cache; bit-for-bit ``mpbcfw-gram`` on 1 device
    mpbcfw-gap         gap-proportional exact-pass sampling + gap-aware
                       eviction (the ``repro.policy`` layer); with
                       ``RunConfig.mesh`` it runs sharded
    mpbcfw-async       pipelined MP-BCFW: exact oracle and cache passes
                       run as two concurrently-dispatched programs per
                       iteration, hiding the costly oracle behind the
                       cache work (``TraceRow.oracle_overlap``)
    mpbcfw-shard-async the same two-program pipeline on the data mesh
    ================== ======================================================

  * **The control loop** is :class:`repro.api.Solver`: streaming
    ``iterate()``, gap-tolerance / time-budget stopping, callbacks,
    checkpoint/resume.

Underneath every MP engine sits **the plane cache**
(:mod:`repro.cache`): one :class:`~repro.cache.PlaneCache` pytree owns
the cached planes, validity, activity clock, and (for the gram engines)
the per-block Gram matrices, all declared by a
:class:`~repro.cache.CacheLayout` — see the demo below.
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro.api import (OracleSpec, RunConfig, Solver,  # noqa: E402
                       build_problem)
from repro.core.oracles import multiclass         # noqa: E402
from repro.core.selection import CostModel        # noqa: E402
from repro.data import synthetic                  # noqa: E402
from repro.launch.mesh import make_data_mesh      # noqa: E402


def cm():
    return CostModel(oracle_cost=0.02, plane_cost=1e-4)


def main():
    x, y = synthetic.usps_like(n=300, f=64, num_classes=10, seed=0)
    problem = multiclass.make_problem(jnp.asarray(x), jnp.asarray(y), 10)
    lam = 1.0 / problem.n

    print("== BCFW (baseline) vs MP-BCFW (paper) — same oracle budget ==")
    for algo in ("bcfw", "mpbcfw"):
        res = Solver(problem, RunConfig(lam=lam, algo=algo, max_iters=10,
                                        cap=32, cost_model=cm())).run()
        last = res.trace[-1]
        print(f"{algo:8s}: exact oracle calls {last.n_exact:5d}  "
              f"approx steps {last.n_approx:6d}  "
              f"duality gap {last.gap:.5f}  dual {last.dual:.5f}")

    # -- streaming iteration + gap-tolerance stopping ----------------------
    solver = Solver(problem, RunConfig(lam=lam, algo="mpbcfw", max_iters=50,
                                       cap=32, gap_tol=1e-3,
                                       cost_model=cm()))
    for row in solver.iterate():            # rows stream as iterations run
        # cache_hit_rate / planes_evicted / oracle_share are measured
        # on-device and drained through the same single host sync as the
        # rest of the row (see the README's Observability section).
        print(f"  iter {row.iteration:2d}  gap {row.gap:.6f}  "
              f"hit {row.cache_hit_rate:.2f}  evicted {row.planes_evicted}  "
              f"oracle share {row.oracle_share:.2f}  "
              f"[{row.dispatches} dispatch / {row.host_syncs} sync]")
    print(f"stopped after {solver.iteration} of 50 iterations "
          f"(gap_tol=1e-3, final gap {solver.trace[-1].gap:.2e})")

    # -- the same run on the mesh-sharded engine ---------------------------
    # (all local devices; on a 1-device host this is bit-for-bit mpbcfw)
    mesh = make_data_mesh()
    res = Solver(problem, RunConfig(lam=lam, algo="mpbcfw-shard", mesh=mesh,
                                    max_iters=10, cap=32,
                                    cost_model=cm())).run()
    last = res.trace[-1]
    syncs = sum(r.host_syncs for r in res.trace)
    disp = sum(r.dispatches for r in res.trace)
    print(f"mpbcfw-shard ({mesh.shape['data']} shard(s)): "
          f"gap {last.gap:.5f}  dual {last.dual:.5f}  "
          f"[{disp} dispatches / {syncs} host syncs over "
          f"{len(res.trace)} iterations]")

    # -- the plane cache is a first-class subsystem ------------------------
    # Every MP engine's working set is a repro.cache.PlaneCache declared
    # by a CacheLayout; gram=True materializes the Sec-3.5 Gram blocks
    # inside the cache (insertions refresh them), which is what lets the
    # sharded gram engine exist — the gram leaf shards with the blocks.
    from repro import cache as plane_cache
    from repro.cache import CacheLayout

    res = Solver(problem, RunConfig(lam=lam, algo="mpbcfw-shard-gram",
                                    mesh=mesh, max_iters=5, cap=32,
                                    cost_model=cm())).run()
    print(f"mpbcfw-shard-gram: gap {res.trace[-1].gap:.5f}  "
          f"ws_mean {res.trace[-1].ws_mean:.1f}  "
          f"[{res.trace[-1].dispatches} dispatch / "
          f"{res.trace[-1].host_syncs} sync per iteration]")
    layout = CacheLayout(cap=8, gram=True, axis="data")
    demo = plane_cache.init(layout, n=4, d=problem.d)
    demo = plane_cache.insert(demo, jnp.asarray(0),
                              jnp.ones((problem.d + 1,)), jnp.asarray(0))
    print(f"PlaneCache: planes {demo.planes.shape}  gram "
          f"{demo.gram.shape}  sizes {np.asarray(plane_cache.sizes(demo))}  "
          f"specs {plane_cache.partition_specs(layout).planes}")

    # -- gap-proportional sampling: the repro.policy layer -----------------
    # mpbcfw-gap swaps the exact pass's uniform epoch for gumbel-top-k
    # sampling proportional to on-device per-block duality-gap estimates
    # (Osokin et al.), spending the costly oracle where the gap still is.
    # gap_frac sets the per-iteration oracle budget; the gap_total /
    # gap_sampled TraceRow columns ride the same single host sync.
    res = Solver(problem, RunConfig(lam=lam, algo="mpbcfw-gap",
                                    max_iters=8, cap=32, gap_frac=0.25,
                                    cost_model=cm())).run()
    for row in res.trace:
        print(f"  mpbcfw-gap iter {row.iteration:2d}  "
              f"sampled {row.gap_sampled:3d}/{problem.n} blocks  "
              f"gap_total {row.gap_total:.5f}  gap {row.gap:.5f}  "
              f"exact calls {row.n_exact:4d}")

    # -- async oracle pipelining: hide the costly oracle -------------------
    # mpbcfw-async dispatches the next blocks' exact oracles (at stale w)
    # and the cache program (eviction + fold-in of the previous pending
    # results + approximate passes) concurrently; the tau-nice fold keeps
    # the dual monotone, and oracle_overlap reports the fraction of the
    # oracle's time hidden behind the cache work.  Under a CostModel the
    # solver credits the hidden span back, so a slow oracle (here 1.0 vs
    # 0.25 per plane-step) makes the pipelined clock visibly faster.
    def slow_cfg(algo):
        # approx_batch >= max_approx_passes keeps the whole approximate
        # batch in one program (no overflow continuations), so the trace
        # shows the bare <= 2 dispatch + 1 sync pipeline contract.
        return RunConfig(lam=lam, algo=algo, max_iters=8, cap=16,
                         max_approx_passes=32, approx_batch=32,
                         cost_model=CostModel(oracle_cost=1.0,
                                              plane_cost=0.25))

    t_fused = Solver(problem, slow_cfg("mpbcfw")).run().trace[-1].time
    res = Solver(problem, slow_cfg("mpbcfw-async")).run()
    ovl = [r.oracle_overlap for r in res.trace]
    print(f"mpbcfw-async: mean oracle_overlap {sum(ovl) / len(ovl):.2f}  "
          f"modeled speedup {t_fused / res.trace[-1].time:.2f}x  "
          f"[{max(r.dispatches for r in res.trace)} dispatches / "
          f"{max(r.host_syncs for r in res.trace)} sync per iteration]")

    # -- record a run: repro.obs (spans + metrics, zero extra syncs) -------
    # The recorder is a Solver callback: it streams JSONL (meta, rows,
    # spans, events, summary), exportable to Perfetto, and summarized by
    # `python -m repro.obs run.jsonl`.
    import tempfile

    from repro.obs import RunRecorder, summarize_run

    with tempfile.NamedTemporaryFile(suffix=".jsonl") as tmp:
        with RunRecorder(tmp.name) as rec:
            Solver(problem, RunConfig(lam=lam, algo="mpbcfw", max_iters=5,
                                      cap=32, cost_model=cm()),
                   recorder=rec).run()
        s = summarize_run(tmp.name)
        print(f"recorded run: {s['iterations']} iterations  "
              f"oracle share {s['oracle_share_mean']:.2f}  "
              f"host_syncs/iter <= "
              f"{s['contract']['host_syncs_per_iter_max']}")

    # -- train -> serve: the repro.serve path ------------------------------
    # The decoder that defines training defines serving.  Train a chain
    # SSVM, export it as a ServableModel (spec + w, persisted through the
    # checkpoint manifest), and serve mixed-length requests through the
    # bucketed continuous-batching StructuredServer: one jitted program
    # per padding bucket, one dispatch per round (ServeLedger-asserted),
    # bit-for-bit equal to per-example spec.decode.
    from repro.checkpoint.manager import CheckpointManager
    from repro.core.oracles import chain
    from repro.serve import ServableModel, StructuredServer

    Xc, Yc, Mc = synthetic.ocr_like(n=80, f=16, num_labels=8,
                                    mean_len=9, max_len=14, seed=3)
    chain_problem = chain.make_problem(jnp.asarray(Xc), jnp.asarray(Yc),
                                       jnp.asarray(Mc), num_labels=8)
    csolver = Solver(chain_problem,
                     RunConfig(lam=1.0 / chain_problem.n, algo="mpbcfw",
                               max_iters=6, cap=32, cost_model=cm()))
    csolver.run()
    with tempfile.TemporaryDirectory() as ckdir:
        csolver.servable().save(CheckpointManager(ckdir), step=6)
        model = ServableModel.load(CheckpointManager(ckdir))
    requests = [{"x": Xc[i, :int(Mc[i].sum())],
                 "y": Yc[i, :int(Mc[i].sum())],
                 "mask": Mc[i, :int(Mc[i].sum())]} for i in range(16)]
    server = StructuredServer(model, batch_size=8)
    served = server.serve(requests)
    ok = all(np.array_equal(lab, np.asarray(
        model.spec.decode(model.w, {k: jnp.asarray(v)
                                    for k, v in r.items()})))
             for lab, r in zip(served, requests))
    rounds, dispatches, _ = server.ledger.counts()
    print(f"served {len(served)} mixed-length chain requests in {rounds} "
          f"rounds ({dispatches} dispatches)  "
          f"bitwise == per-example decode: {ok}")

    # -- accuracy of the learned (averaged) predictor ----------------------
    res = Solver(problem, RunConfig(lam=lam, algo="mpbcfw-avg",
                                    max_iters=10, cap=32,
                                    cost_model=CostModel())).run()
    w = res.w_avg.reshape(10, -1)
    pred = np.argmax(x @ w.T, axis=1)
    print(f"train accuracy (mpbcfw-avg): {np.mean(pred == y):.3f}")

    # -- a custom task: define an OracleSpec, get every engine for free ----
    class OrdinalSpec(OracleSpec):
        """Ordinal regression, absolute-error loss: labels 0..C-1,
        Delta(y, y') = |y - y'| / (C-1).  Everything the optimizer needs
        is these five methods; build_problem assembles the max-oracle."""

        C = 5

        def dim(self, data):
            return self.C * int(data["x"].shape[-1])

        def truth(self, ex):
            return ex["y"]

        def decode(self, w, ex):
            x, y = ex["x"], ex["y"]
            wc = w.reshape(self.C, x.shape[0])
            delta = jnp.abs(jnp.arange(self.C) - y) / (self.C - 1.0)
            return jnp.argmax(wc @ x + delta)   # loss-augmented argmax

        def features(self, ex, y):
            x = ex["x"]
            return (jnp.zeros((self.C, x.shape[0]), x.dtype)
                    .at[y].add(x)).reshape(-1)

        def loss(self, ex, y):
            return jnp.abs(y - ex["y"]).astype(jnp.float32) / (self.C - 1.0)

    r = np.random.RandomState(1)
    xo = r.randn(200, 16).astype(np.float32)
    yo = np.clip((xo @ r.randn(16) * 0.7 + 2.5), 0, 4.99).astype(np.int32)
    ordinal = build_problem(OrdinalSpec(), {"x": jnp.asarray(xo),
                                            "y": jnp.asarray(yo)})
    res = Solver(ordinal, RunConfig(lam=1.0 / ordinal.n, algo="mpbcfw",
                                    max_iters=10, cap=16,
                                    cost_model=cm())).run()
    wo = res.w.reshape(5, -1)
    mae = np.mean(np.abs(np.argmax(xo @ wo.T, axis=1) - yo))
    print(f"custom OrdinalSpec via mpbcfw: gap {res.trace[-1].gap:.5f}  "
          f"train MAE {mae:.3f}")


if __name__ == "__main__":
    main()
