"""Quickstart: train a multiclass SSVM with MP-BCFW and compare to BCFW.

    PYTHONPATH=src python examples/quickstart.py

Algorithms (``repro.core.driver.ALGORITHMS``):

  ================== ======================================================
  name               what it runs
  ================== ======================================================
  fw                 batch Frank-Wolfe (paper Alg. 1)
  ssg                stochastic subgradient baseline
  bcfw / bcfw-avg    block-coordinate FW (Alg. 2), optionally averaged
  mpbcfw             multi-plane BCFW (Alg. 3) — one fused program per
                     outer iteration (exact pass + slope-ruled approximate
                     batch), one host sync per iteration
  mpbcfw-avg         + two-track weighted averaging (Sec. 3.6)
  mpbcfw-gram        + the Sec-3.5 Gram-cache inner loop (same fused
                     program, Gram cache threaded through)
  mpbcfw-shard       mpbcfw on a 1-D data mesh (``RunConfig.mesh``, default
                     all local devices): tau-nice exact epoch + sharded
                     approximate batch, still one program / one sync per
                     iteration; bit-for-bit ``mpbcfw`` on a 1-device mesh
  mpbcfw-shard-avg   + averaging
  mpbcfw-shard-tau   explicit tau-nice chunk size via ``RunConfig.tau``
  ================== ======================================================
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro.core import driver                     # noqa: E402
from repro.core.oracles import multiclass         # noqa: E402
from repro.core.selection import CostModel        # noqa: E402
from repro.data import synthetic                  # noqa: E402
from repro.launch.mesh import make_data_mesh      # noqa: E402


def main():
    x, y = synthetic.usps_like(n=300, f=64, num_classes=10, seed=0)
    problem = multiclass.make_problem(jnp.asarray(x), jnp.asarray(y), 10)
    lam = 1.0 / problem.n

    print("== BCFW (baseline) vs MP-BCFW (paper) — same oracle budget ==")
    for algo in ("bcfw", "mpbcfw"):
        cfg = driver.RunConfig(lam=lam, algo=algo, max_iters=10, cap=32,
                               cost_model=CostModel(oracle_cost=0.02,
                                                    plane_cost=1e-4))
        res = driver.run(problem, cfg)
        last = res.trace[-1]
        print(f"{algo:8s}: exact oracle calls {last.n_exact:5d}  "
              f"approx steps {last.n_approx:6d}  "
              f"duality gap {last.gap:.5f}  dual {last.dual:.5f}")

    # the same run on the mesh-sharded engine (all local devices; on a
    # 1-device host this is bit-for-bit the mpbcfw run above)
    mesh = make_data_mesh()
    cfg = driver.RunConfig(lam=lam, algo="mpbcfw-shard", mesh=mesh,
                           max_iters=10, cap=32,
                           cost_model=CostModel(oracle_cost=0.02,
                                                plane_cost=1e-4))
    res = driver.run(problem, cfg)
    last = res.trace[-1]
    syncs = sum(r.host_syncs for r in res.trace)
    disp = sum(r.dispatches for r in res.trace)
    print(f"mpbcfw-shard ({mesh.shape['data']} shard(s)): "
          f"gap {last.gap:.5f}  dual {last.dual:.5f}  "
          f"[{disp} dispatches / {syncs} host syncs over "
          f"{len(res.trace)} iterations]")

    # accuracy of the learned predictor
    cfg = driver.RunConfig(lam=lam, algo="mpbcfw-avg", max_iters=10, cap=32,
                           cost_model=CostModel())
    res = driver.run(problem, cfg)
    w = res.w_avg.reshape(10, -1)
    pred = np.argmax(x @ w.T, axis=1)
    print(f"train accuracy (mpbcfw-avg): {np.mean(pred == y):.3f}")


if __name__ == "__main__":
    main()
