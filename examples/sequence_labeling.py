"""Sequence labeling (OCR-style) with the chain/Viterbi max-oracle.

Shows the paper's costly-oracle regime: the Viterbi oracle is much more
expensive than an approximate (cached-plane) step, so the slope rule runs
many approximate passes per exact pass.

    PYTHONPATH=src python examples/sequence_labeling.py
"""
import sys

sys.path.insert(0, "src")

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro.api import RunConfig, Solver           # noqa: E402
from repro.core.oracles import chain              # noqa: E402
from repro.core.oracles.chain import viterbi_decode  # noqa: E402
from repro.core.selection import CostModel        # noqa: E402
from repro.data import synthetic                  # noqa: E402


def main():
    X, Y, M = synthetic.ocr_like(n=150, f=32, num_labels=12, mean_len=8,
                                 max_len=12, seed=0)
    problem = chain.make_problem(jnp.asarray(X), jnp.asarray(Y),
                                 jnp.asarray(M), 12)
    lam = 1.0 / problem.n
    cfg = RunConfig(
        lam=lam, algo="mpbcfw", max_iters=10, cap=32,
        cost_model=CostModel(oracle_cost=0.3, plane_cost=1e-4))
    res = Solver(problem, cfg).run()
    for r in res.trace[::3] + [res.trace[-1]]:
        print(f"iter {r.iteration:2d}  approx-passes {r.approx_passes:3d}  "
              f"ws {r.ws_mean:5.1f}  gap {r.gap:.5f}")

    # token accuracy with the learned weights
    C, f = 12, 32
    w = jnp.asarray(res.w)
    wu, wp = w[: C * f].reshape(C, f), w[C * f:].reshape(C, C)

    @jax.jit
    def predict(x, m):
        return viterbi_decode(x @ wu.T, wp, m)

    correct = total = 0
    for i in range(problem.n):
        y_hat = np.asarray(predict(jnp.asarray(X[i]), jnp.asarray(M[i])))
        correct += int(((y_hat == Y[i]) & M[i]).sum())
        total += int(M[i].sum())
    print(f"token accuracy: {correct / total:.3f}")


if __name__ == "__main__":
    main()
