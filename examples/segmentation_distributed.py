"""HorseSeg-style segmentation with a costly graph oracle, trained with
the mesh-sharded tau-nice MP-BCFW engine (`repro.shard`) — including
simulated stragglers whose oracle results are replaced by their cached
planes from one *batched* scoring call (the paper's approximate oracle
doubling as the fault-tolerance path).

Each outer iteration is ONE fused device program — TTL eviction, the
tau-nice exact epoch (parallel oracles at the chunk's stale w under
shard_map, sequential monotone fold-in), and the slope-ruled batch of
sharded approximate passes (one psum per pass), with the slope clock
seeded from the on-device dual; the host dispatches once and syncs once
per iteration to read telemetry.  The old host chunk loop
(`repro.core.distributed.tau_nice_pass`) is gone and fails with
directions here.  (The same loop is reachable from the public entry
point as `repro.api.Solver` with `algo="mpbcfw-shard"`; this example
drives the engine directly to show the straggler `done` mask.)

On a multi-device host (or with ``--xla_force_host_platform_device_count=N``
set before jax initializes; see ``repro.launch.mesh``) the same script
shards blocks, plane cache, and oracles over all N devices.

    PYTHONPATH=src python examples/segmentation_distributed.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro.core import distributed, mpbcfw            # noqa: E402
from repro.core.oracles import graph                   # noqa: E402
from repro.core.ssvm import dual_value, duality_gap    # noqa: E402
from repro.data import synthetic                       # noqa: E402
from repro.ft import StragglerPolicy, simulate_oracle_outcomes  # noqa: E402
from repro.launch.mesh import make_data_mesh           # noqa: E402
from repro.shard import ShardEngine                    # noqa: E402


def main():
    n, tau, batch = 64, 8, 6
    Xg, Yg, Mg, Eg, EMg, Cg = synthetic.horseseg_like(
        n=n, grid=(8, 8), f=48, seed=0)
    problem = graph.make_problem(
        jnp.asarray(Xg), jnp.asarray(Yg), jnp.asarray(Mg), jnp.asarray(Eg),
        jnp.asarray(EMg), jnp.asarray(Cg), num_sweeps=30)
    lam = 1.0 / n

    mesh = make_data_mesh()
    engine = ShardEngine(problem, mesh, lam=lam)
    mp = engine.init_state(cap=16)
    rng = np.random.RandomState(0)
    policy = StragglerPolicy(straggler_prob=0.05)

    # The deprecated host chunk loop fails loudly with directions:
    try:
        distributed.tau_nice_pass()
    except RuntimeError as e:
        print(f"(removed API guard: {str(e).splitlines()[0]} ...)\n")

    f_prev = 0.0
    for epoch in range(8):
        perm = jnp.asarray(rng.permutation(n))
        perms = jnp.asarray(np.stack([rng.permutation(n)
                                      for _ in range(batch)]))
        done_np, lat = simulate_oracle_outcomes(n, policy, rng)
        done = jnp.asarray(done_np.reshape(n // tau, tau))
        clock = mpbcfw.make_slope_clock(0.0, f_prev, float(n), 1e-3)
        mp, clock, stats = engine.outer_iteration(
            mp, perm, perms, clock, tau=tau, ttl=10, done=done)
        st = engine.read_stats(stats)  # the epoch's single host sync
        f_prev = float(dual_value(mp.inner.phi, lam))
        gap = float(duality_gap(problem, mp.inner, lam))
        print(f"epoch {epoch}  dual {f_prev:.5f}  gap {gap:.5f}"
              f"  approx-passes {int(st.passes_run)}"
              f"  oracles-ok {int(done_np.sum())}/{n}"
              f"  (worst latency {lat.max():.1f}x median)")
    print(f"\nstraggler-tolerant sharded MP-BCFW converged on "
          f"{engine.n_shards} shard(s): "
          f"{engine.ledger.host_syncs} host syncs, "
          f"{engine.ledger.collectives} collectives, "
          f"{engine.ledger.dispatches} dispatches over 8 epochs "
          f"({engine.psums_per_approx_pass} psum per approximate pass).")


if __name__ == "__main__":
    main()
