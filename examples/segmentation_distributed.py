"""HorseSeg-style segmentation with a costly graph oracle, trained with the
*distributed* tau-nice MP-BCFW pass — including simulated stragglers whose
oracle results are replaced by cached planes (the paper's approximate
oracle doubling as the fault-tolerance path).

    PYTHONPATH=src python examples/segmentation_distributed.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro.core import distributed, mpbcfw             # noqa: E402
from repro.core.oracles import graph                   # noqa: E402
from repro.core.ssvm import dual_value, duality_gap    # noqa: E402
from repro.data import synthetic                       # noqa: E402
from repro.ft import StragglerPolicy, simulate_oracle_outcomes  # noqa: E402


def main():
    n, tau = 64, 8
    Xg, Yg, Mg, Eg, EMg, Cg = synthetic.horseseg_like(
        n=n, grid=(8, 8), f=48, seed=0)
    problem = graph.make_problem(
        jnp.asarray(Xg), jnp.asarray(Yg), jnp.asarray(Mg), jnp.asarray(Eg),
        jnp.asarray(EMg), jnp.asarray(Cg), num_sweeps=30)
    lam = 1.0 / n
    mp = mpbcfw.init_mp_state(problem, cap=16)
    rng = np.random.RandomState(0)
    policy = StragglerPolicy(straggler_prob=0.05)

    for epoch in range(8):
        mp = mpbcfw.begin_iteration(mp, ttl=10)
        perm = jnp.asarray(rng.permutation(n))
        done_np, lat = simulate_oracle_outcomes(n, policy, rng)
        done = jnp.asarray(done_np.reshape(n // tau, tau))
        mp = distributed.tau_nice_pass(problem, mp, perm, lam, tau=tau,
                                       done=done)
        gap = float(duality_gap(problem, mp.inner, lam))
        print(f"epoch {epoch}  dual {float(dual_value(mp.inner.phi, lam)):.5f}"
              f"  gap {gap:.5f}  oracles-ok {int(done_np.sum())}/{n}"
              f"  (worst latency {lat.max():.1f}x median)")
    print("straggler-tolerant distributed MP-BCFW converged.")


if __name__ == "__main__":
    main()
