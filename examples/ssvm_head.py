"""SSVM head on backbone features — the paper's technique integrated with
the LM substrate: a chain-CRF tag head over qwen2-family token features,
trained with MP-BCFW (convex given the frozen features).

    PYTHONPATH=src python examples/ssvm_head.py
"""
import sys

sys.path.insert(0, "src")

import jax                # noqa: E402
import jax.numpy as jnp   # noqa: E402
import numpy as np        # noqa: E402

from repro import configs                          # noqa: E402
from repro.api import RunConfig, Solver            # noqa: E402
from repro.core.selection import CostModel         # noqa: E402
from repro.models import common, registry          # noqa: E402
from repro.trainer.ssvm_head import backbone_chain_problem  # noqa: E402


def main():
    cfg = configs.reduced_config("qwen2-0.5b")
    params = common.init_params(registry.param_specs(cfg),
                                jax.random.PRNGKey(0))
    # synthetic tagging task: tag = f(token id) with noise
    rng = np.random.RandomState(0)
    n, L, tags = 48, 12, 5
    tokens = rng.randint(0, cfg.vocab_size, (n, L)).astype(np.int32)
    gold = (tokens % tags).astype(np.int32)
    mask = np.ones((n, L), bool)

    problem = backbone_chain_problem(
        cfg, params, jnp.asarray(tokens), jnp.asarray(gold),
        jnp.asarray(mask), tags)
    lam = 1.0 / problem.n
    cfg_run = RunConfig(lam=lam, algo="mpbcfw", max_iters=8, cap=16,
                        cost_model=CostModel(oracle_cost=0.5))
    res = Solver(problem, cfg_run).run()
    for r in res.trace[::2] + [res.trace[-1]]:
        print(f"iter {r.iteration:2d}  gap {r.gap:.5f}  "
              f"approx-passes {r.approx_passes}")
    print("SSVM head trained on backbone features with MP-BCFW.")


if __name__ == "__main__":
    main()
